"""Shared training loops for the paper-network benchmarks (CPU-scaled)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.optim import OptConfig, apply_updates, init_opt_state


def train_classifier(init_fn, apply_fn, data_fn, *, steps=300, lr=2e-3,
                     act_levels=0, n_weights=0, cluster_every=100,
                     opt="adam", seed=0, method="laplacian_l1",
                     subsample=1.0, dropout=0.0):
    """Generic classification trainer with the paper's two quantizations.

    apply_fn(params, x, act_levels, key) -> logits.
    data_fn(step) -> {'x', 'y'}.
    Returns (params, qstate, wq).
    """
    params = init_fn(jax.random.PRNGKey(seed))
    ocfg = OptConfig(name=opt, lr=lr)
    opt_state = init_opt_state(params, ocfg)
    wq = WeightQuantConfig(num_weights=n_weights, method=method,
                           interval=cluster_every, subsample=subsample) \
        if n_weights else WeightQuantConfig()
    qstate = init_state(wq)

    @jax.jit
    def step_fn(params, opt_state, x, y, key):
        def loss_fn(p):
            logits = apply_fn(p, x, act_levels, key)
            lse = jax.nn.logsumexp(logits, -1)
            true = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
            return jnp.mean(lse - true)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = apply_updates(params, g, opt_state, ocfg)
        return params, opt_state, loss

    for s in range(steps):
        if wq.due(s):
            params, qstate = cluster_params(params, wq, qstate, s,
                                            jax.random.PRNGKey(1000 + s))
        b = data_fn(s)
        params, opt_state, loss = step_fn(params, opt_state, b["x"], b["y"],
                                          jax.random.PRNGKey(s))
    if wq.enabled:
        params, qstate = cluster_params(params, wq, qstate, steps,
                                        jax.random.PRNGKey(99))
    return params, qstate, wq


def train_regressor(init_fn, apply_fn, data_fn, *, steps=300, lr=2e-3,
                    act_levels=0, n_weights=0, cluster_every=100, seed=0):
    """L2-regression trainer (auto-encoders, parabola)."""
    params = init_fn(jax.random.PRNGKey(seed))
    ocfg = OptConfig(name="adam", lr=lr)
    opt_state = init_opt_state(params, ocfg)
    wq = WeightQuantConfig(num_weights=n_weights, method="laplacian_l1",
                           interval=cluster_every) if n_weights else \
        WeightQuantConfig()
    qstate = init_state(wq)

    @jax.jit
    def step_fn(params, opt_state, x, y):
        def loss_fn(p):
            return jnp.mean((apply_fn(p, x, act_levels) - y) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = apply_updates(params, g, opt_state, ocfg)
        return params, opt_state, loss

    loss = None
    for s in range(steps):
        if wq.due(s):
            params, qstate = cluster_params(params, wq, qstate, s,
                                            jax.random.PRNGKey(1000 + s))
        b = data_fn(s)
        y = b.get("y", b["x"])
        params, opt_state, loss = step_fn(params, opt_state, b["x"], y)
    if wq.enabled:
        params, qstate = cluster_params(params, wq, qstate, steps,
                                        jax.random.PRNGKey(99))
    return params, qstate, float(loss)


def recall_at(apply_fn, data_fn, params, act_levels, ks=(1, 5), n_batches=4,
              start=5000):
    hits = {k: 0 for k in ks}
    tot = 0
    for s in range(start, start + n_batches):
        b = data_fn(s)
        logits = np.asarray(apply_fn(params, b["x"], act_levels, None))
        order = np.argsort(-logits, axis=-1)
        y = np.asarray(b["y"])
        for k in ks:
            hits[k] += (order[:, :k] == y[:, None]).any(-1).sum()
        tot += y.size
    return {k: hits[k] / tot for k in ks}


def timer(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs
