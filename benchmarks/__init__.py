"""Benchmark harness: one module per paper table/figure + the roofline
analysis over the dry-run artifacts.  Entry point: python -m benchmarks.run."""
