"""Paper Fig. 7: auto-encoding (real-valued regression) under both
quantizations — FC and conv architectures, relative-to-baseline L2."""

from __future__ import annotations

from functools import partial

from benchmarks._common import train_regressor
from repro.data.synthetic import smooth_images
from repro.models import papernets as PN


def run(steps=300):
    rows = []
    # --- FC auto-encoder -----------------------------------------------------
    data_fc = lambda s: {"x": smooth_images(s, 16, 16).get("x").reshape(16, -1)}
    grid = [("tanh", 0, 0), ("relu6", 0, 0), ("tanhD(32)", 32, 0),
            ("tanhD(256)", 256, 0), ("tanh |W|=100", 0, 100),
            ("tanh |W|=1000", 0, 1000), ("tanhD(32) |W|=1000", 32, 1000)]
    base = None
    for label, levels, nw in grid:
        kind = "relu6" if label.startswith("relu") else "tanh"
        init = lambda k: PN.fc_autoencoder_init(k, 16 * 16 * 3, n=0.5)
        ap = lambda p, x, lv: PN.fc_autoencoder_apply(p, x, kind, lv)
        _, _, mse = train_regressor(init, ap, data_fc, steps=steps,
                                    act_levels=levels, n_weights=nw,
                                    cluster_every=80)
        if base is None:
            base = mse
        rows.append(("fig7_fc_ae", label, f"{mse / base:.3f}"))
    # --- conv auto-encoder ---------------------------------------------------
    data_cv = lambda s: smooth_images(s, 8, 32)
    base = None
    for label, levels, nw in [("tanh", 0, 0), ("tanhD(32)", 32, 0),
                              ("tanh |W|=1000", 0, 1000),
                              ("tanhD(32) |W|=1000", 32, 1000)]:
        init = lambda k: PN.conv_autoencoder_init(k, n=0.5)
        ap = lambda p, x, lv: PN.conv_autoencoder_apply(p, x, "tanh", lv)
        _, _, mse = train_regressor(init, ap, data_cv, steps=steps,
                                    act_levels=levels, n_weights=nw,
                                    cluster_every=80)
        if base is None:
            base = mse
        rows.append(("fig7_conv_ae", label, f"{mse / base:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
