"""Paper §3.1: MNIST-style classification under both quantizations.

Trains the same MLP four ways (continuous, |A|=32, |W|=1000, both) and
prints the accuracy table — the CPU-scale version of the paper's Fig. 6.

    PYTHONPATH=src python examples/train_mnist_quantized.py
"""

import sys
sys.path.insert(0, ".")  # for benchmarks._common when run from repo root

from functools import partial

from benchmarks._common import recall_at, train_classifier
from repro.data.synthetic import pseudo_mnist_batch
from repro.models import papernets as PN


def apply_fn(p, x, act_levels, key):
    return PN.mlp_apply(p, x, "tanh", act_levels)


def main():
    init = lambda k: PN.mlp_init(k, 784, [32, 32], 10)
    data = lambda s: pseudo_mnist_batch(s, 64)
    print(f"{'variant':28s} accuracy")
    for label, levels, nw in [("continuous tanh", 0, 0),
                              ("tanhD(32)", 32, 0),
                              ("tanh, |W|=1000", 0, 1000),
                              ("tanhD(32) + |W|=1000", 32, 1000),
                              ("tanhD(32) + |W|=100", 32, 100)]:
        params, _, _ = train_classifier(init, apply_fn, data, steps=300,
                                        act_levels=levels, n_weights=nw,
                                        cluster_every=75)
        acc = recall_at(apply_fn, data, params, levels)[1]
        print(f"{label:28s} {acc:.4f}")


if __name__ == "__main__":
    main()
