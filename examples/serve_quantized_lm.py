"""Serve a codebook-compressed LM with an int8 KV cache — the TPU-side
deployment story (DESIGN.md §2): weights live in HBM as 10-bit-class
indices + a tiny codebook; the KV cache is int8.

    PYTHONPATH=src python examples/serve_quantized_lm.py [--arch NAME]
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core.export import memory_report
from repro.core.quantizer import cluster_params, codebook_indices, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(kv_quant=True,
                                                   dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = cfg.quantized().wq
    params, qstate = cluster_params(params, wq, init_state(wq), wq.interval,
                                    jax.random.PRNGKey(1))
    idx_tree, _ = codebook_indices(params, wq, qstate)
    print("[weights]", memory_report(idx_tree, wq.num_weights, 32).row())
    cparams = to_codebook_params(params, wq, qstate, min_size=1024)

    engine = ServeEngine(model, cparams, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, 8)) for _ in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n = args.requests * args.max_new
    print(f"[serve] {n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s, CPU, "
          f"int8 KV cache, codebook weights)")
    print("sample continuation:", outs[0][8:])


if __name__ == "__main__":
    main()
