"""Serve a codebook-compressed LM through all three matmul backends — the
deployment story of the paper's §4 on TPU-shaped hardware (DESIGN.md §2–§3):
weights live in HBM as 10-bit-class indices + a tiny codebook; the KV cache
is int8; decode is a jitted loop with continuous batching.

The same compressed params are served three ways:
    dense     gather the codebook, XLA dot          (baseline numerics)
    codebook  Pallas codebook_matmul                (TPU deployment path)
    lut       Pallas lut_matmul integer engine      (faithful §4: no
              multiplications in the contraction)

    PYTHONPATH=src python examples/serve_quantized_lm.py [--arch NAME]
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core.export import memory_report
from repro.core.quantizer import cluster_params, codebook_indices, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--lut-max-new", type=int, default=8,
                    help="lut interprets the Pallas kernel per layer on "
                         "CPU; keep its demo short")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(kv_quant=True,
                                                   dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = cfg.quantized().wq
    params, qstate = cluster_params(params, wq, init_state(wq), wq.interval,
                                    jax.random.PRNGKey(1))
    idx_tree, _ = codebook_indices(params, wq, qstate)
    print("[weights]", memory_report(idx_tree, wq.num_weights, 32).row())
    cparams = to_codebook_params(params, wq, qstate, min_size=1024)

    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab, 8)]
               for _ in range(args.requests)]

    for backend in ("dense", "codebook", "lut"):
        max_new = args.lut_max_new if backend == "lut" else args.max_new
        engine = ServeEngine(model, cparams, max_len=64, backend=backend,
                             max_batch=args.requests)
        # warm with the shapes that will be timed (jit retraces on change)
        engine.generate(prompts, max_new=max_new)
        t0 = time.time()
        outs = engine.generate(prompts, max_new=max_new)
        dt = time.time() - t0
        n = args.requests * max_new
        print(f"[{backend:>8}] {n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s, "
              f"int8 KV cache, codebook weights)")
        print(f"           continuation: {outs[0][8:]}")


if __name__ == "__main__":
    main()
