"""Serve a codebook-compressed LM through all three matmul backends — the
deployment story of the paper's §4 on TPU-shaped hardware (DESIGN.md §2–§3):
weights live in HBM as 10-bit-class indices + a tiny codebook; the KV cache
is int8; decode is a jitted loop with continuous batching.

The same compressed params are served three ways:
    dense     gather the codebook, XLA dot          (baseline numerics)
    codebook  Pallas codebook_matmul                (TPU deployment path)
    lut       Pallas lut_matmul integer engine      (faithful §4: no
              multiplications in the contraction)

each with in-graph numerics probes on (DESIGN.md §14) — a per-backend
saturation / accumulator-headroom / KV-error table prints after the
three runs, the runtime evidence that the discretized paths are healthy.

then once more through the **paged KV cache** (DESIGN.md §8): requests
share a common system prompt, so their full prompt pages are computed and
stored once — the prefix-cache hit rate and the int8-page pool footprint
are printed against the dense slab.

Finally, **speculative decoding** (DESIGN.md §9) pairs the two ends of the
paper's precision spectrum: the SAME compressed params run as a coarse-grid
lut-tier *draft* proposing k tokens per round for the codebook-tier
*target*, which verifies all k+1 positions in one forward — identical
greedy tokens, fewer target rounds.

    PYTHONPATH=src python examples/serve_quantized_lm.py [--arch NAME]
        [--page-size N] [--kv-dtype {bf16,int8}] [--no-prefix-cache]
        [--spec-k N]
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core.export import memory_report
from repro.core.quantizer import cluster_params, codebook_indices, init_state
from repro.models.model_zoo import build
from repro.serving import (ServeEngine, SpecConfig, Telemetry,
                           to_codebook_params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--lut-max-new", type=int, default=8,
                    help="lut interprets the Pallas kernel per layer on "
                         "CPU; keep its demo short")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default="int8", choices=("bf16", "int8"))
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction)
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per speculative verify round")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(kv_quant=True,
                                                   dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = cfg.quantized().wq
    params, qstate = cluster_params(params, wq, init_state(wq), wq.interval,
                                    jax.random.PRNGKey(1))
    idx_tree, _ = codebook_indices(params, wq, qstate)
    print("[weights]", memory_report(idx_tree, wq.num_weights, 32).row())
    cparams = to_codebook_params(params, wq, qstate, min_size=1024)

    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab, 8)]
               for _ in range(args.requests)]

    # one metrics registry for the whole run (DESIGN.md §13): each engine
    # below attaches its subsystem stats, and the end-of-run summary reads
    # them from one place instead of per-subsystem hand-rolled prints
    tel = Telemetry()
    tel.attach_kernel_counters()

    probe_rows = {}
    for backend in ("dense", "codebook", "lut"):
        max_new = args.lut_max_new if backend == "lut" else args.max_new
        engine = ServeEngine(model, cparams, max_len=64, backend=backend,
                             max_batch=args.requests, probes=True)
        # warm with the shapes that will be timed (jit retraces on change)
        engine.generate(prompts, max_new=max_new)
        engine.reset_probes()
        t0 = time.time()
        outs = engine.generate(prompts, max_new=max_new)
        dt = time.time() - t0
        n = args.requests * max_new
        print(f"[{backend:>8}] {n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s, "
              f"int8 KV cache, codebook weights)")
        print(f"           continuation: {outs[0][8:]}")
        probe_rows[backend] = engine.numerics()

    # --- numerics health (DESIGN.md §14) -------------------------------------
    # the probes that rode the timed runs above: how hard each backend's
    # discretization actually worked on this model — activations clipped
    # to the level grid, int32 margin left in the lut contraction, and the
    # error the int8 KV round-trip put on what attention reads back
    print("[numerics] per-backend discretization health (worst layer):")
    print(f"           {'backend':<9} {'sat rate':>9} {'acc headroom':>13} "
          f"{'kv err max':>11} {'widx oob':>9}")
    for be, num in probe_rows.items():
        sat = max(num["sat_rate"] or [0.0])
        hr = min(num["headroom_bits"] or [31.0])
        kv = max(num["kv_err_max"] or [0.0])
        print(f"           {be:<9} {100 * sat:>8.2f}% {hr:>8.1f} bits "
              f"{kv:>11.4f} {num['widx_oob']:>9}")

    # --- paged KV cache + prefix reuse (DESIGN.md §8) ------------------------
    # N requests sharing one system prompt: its full pages are computed and
    # stored ONCE; every later request's admission re-links them (hit) and
    # pays only for its distinct user suffix.
    plain = build(cfg.replace(kv_quant=False))  # pages carry the quantization
    system = [int(t) for t in rng.integers(0, cfg.vocab, 4 * args.page_size)]
    shared = [system + [int(t) for t in rng.integers(0, cfg.vocab, 4)]
              for _ in range(args.requests)]
    engine = ServeEngine(plain, cparams,
                         max_len=len(shared[0]) + args.max_new // 2 + 8,
                         max_batch=args.requests, paged=True,
                         page_size=args.page_size, kv_dtype=args.kv_dtype,
                         prefix_cache=args.prefix_cache)
    tel.attach_engine(engine)
    outs = engine.serve(shared, max_new=args.max_new // 2)
    st = engine.pool.stats
    print(f"[   paged] shared system prompt ({len(system)} tokens × "
          f"{args.requests} requests): peak cache "
          f"{engine.pool.bytes_per_page() * st.peak_pages_in_use / 1e6:.3f}MB"
          f" vs {engine.dense_cache_bytes() / 1e6:.3f}MB dense slab "
          f"({args.kv_dtype} pages, {args.page_size} tokens/page)")
    print(f"           continuation: {outs[0][len(shared[0]):]}")

    # --- speculative decoding (DESIGN.md §9) ---------------------------------
    # Both ends of the paper's spectrum in one engine: the SAME index-form
    # params propose through the faithful integer engine on a COARSE 512-
    # level grid (the cheap tier) and verify through the codebook MXU path
    # (the accurate tier).  Greedy output is identical to non-speculative
    # serving; the target runs one k+1-token forward per round instead of
    # one forward per token.
    k = args.spec_k
    target = ServeEngine(model, cparams, max_len=64 + k, max_batch=4,
                         backend="codebook")
    spec_eng = ServeEngine(model, cparams, max_len=64 + k, max_batch=4,
                           backend="codebook",
                           spec=SpecConfig(draft="model", k=k,
                                           draft_params=cparams,
                                           draft_backend="lut",
                                           lut_levels=512))
    tel.attach_engine(spec_eng)
    want = target.serve(prompts, max_new=args.max_new // 2)
    got = spec_eng.serve(prompts, max_new=args.max_new // 2)
    print(f"[    spec] lut(512)-tier draft -> codebook-tier target, k={k}: "
          f"{'identical tokens' if got == want else 'DIVERGED'} over "
          f"{args.requests * (args.max_new // 2)} tokens")
    print(f"           continuation: {got[0][8:]}")

    # the end-of-run rollup — prefix hit rate, spec acceptance, kernel
    # dispatch routes — read from the registry the subsystems fed above
    print(tel.summary())


if __name__ == "__main__":
    main()
