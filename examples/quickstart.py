"""Quickstart: the paper's pipeline end-to-end in two minutes on CPU.

1.  Train a small LM with quantized activations (|A|=16) and periodic
    weight clustering (|W|=256, Laplacian-L1).
2.  Export the weights to codebook-index form (§4) + memory report.
3.  Serve a few tokens from the compressed network.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

import repro.configs as configs
from repro.core.export import memory_report
from repro.core.quantizer import codebook_indices
from repro.launch.train import TrainLoopConfig, train
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params


def main():
    cfg = configs.get("qwen3-1.7b").reduced().quantized(levels=16,
                                                        n_weights=256)
    cfg = cfg.replace(wq=cfg.wq.__class__(num_weights=256,
                                          method="laplacian_l1",
                                          interval=20))
    print(f"== training {cfg.name} (reduced) with |A|={cfg.act_levels}, "
          f"|W|={cfg.wq.num_weights} ==")
    loop = TrainLoopConfig(steps=80, batch=8, seq=64, lr=3e-3)
    params, qstate, history = train(cfg, loop)
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

    print("\n== §4 export: codebook indices + memory accounting ==")
    idx_tree, _ = codebook_indices(params, cfg.wq, qstate)
    print(memory_report(idx_tree, cfg.wq.num_weights, cfg.act_levels).row())

    cparams = to_codebook_params(params, cfg.wq, qstate, min_size=1024)
    print("\n== serving from the compressed network ==")
    engine = ServeEngine(build(cfg), cparams, max_len=48)
    out = engine.generate([[5, 6, 7, 8]], max_new=12)[0]
    print("prompt [5,6,7,8] ->", out[4:])


if __name__ == "__main__":
    main()
