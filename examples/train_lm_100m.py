"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the paper's technique enabled, checkpointing, and a resume test.

~97M params (d=640, 10 layers, ff=2560, vocab 50k, qwen3-style blocks).
On this CPU container a step is seconds; the same script drives the
production mesh unchanged (train() takes a mesh).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 200
"""

import argparse
import math

import jax

import repro.configs as configs
from repro.core.quantizer import WeightQuantConfig
from repro.launch.train import TrainLoopConfig, train
from repro.launch.steps import abstract_params
from repro.models.model_zoo import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = configs.get("qwen3-1.7b").replace(
        name="qwen3-100m", n_layers=10, d_model=640, d_ff=2560,
        n_heads=10, n_kv=5, head_dim=64, vocab=50048, dtype="float32",
        act_levels=32,
        wq=WeightQuantConfig(num_weights=1000, method="laplacian_l1",
                             interval=100),
        microbatches=1)
    params_abs = abstract_params(build(cfg))
    n = sum(int(math.prod(x.shape)) for x in jax.tree.leaves(params_abs))
    print(f"== {cfg.name}: {n / 1e6:.1f}M params, |A|=32, |W|=1000, "
          f"cluster every 100 steps ==")

    loop = TrainLoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                           lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                           log_every=20)
    params, qstate, history = train(cfg, loop)
    print("final:", history[-1])
    if qstate.codebooks:
        print(f"codebook: {qstate.codebooks[''].shape[0]} unique weights "
              f"(last clustered at step {qstate.last_step})")
    else:
        print(f"(no clustering event yet — fires every "
              f"{cfg.wq.interval} steps)")


if __name__ == "__main__":
    main()
